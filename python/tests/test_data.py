"""Dataset generator invariants."""

import numpy as np

from compile import data as data_mod
from compile.tensorfile import read_tensors, write_tensors


class TestSyntheticDataset:
    def test_determinism(self):
        a = data_mod.make_dataset(data_mod.SPECS["m20"])
        b = data_mod.make_dataset(data_mod.SPECS["m20"])
        np.testing.assert_array_equal(a.train_x, b.train_x)
        np.testing.assert_array_equal(a.eval_y, b.eval_y)

    def test_shapes(self):
        spec = data_mod.SPECS["m20"]
        ds = data_mod.make_dataset(spec)
        assert ds.train_x.shape == (spec.n_train, data_mod.TOKENS, spec.dim)
        assert ds.calib_x.shape == (spec.n_calib, data_mod.TOKENS, spec.dim)
        assert ds.eval_x.shape == (spec.n_eval, data_mod.TOKENS, spec.dim)
        assert ds.train_y.shape == (spec.n_train,)
        assert ds.train_y.dtype == np.int32

    def test_standardized(self):
        ds = data_mod.make_dataset(data_mod.SPECS["m20"])
        flat = ds.train_x.reshape(-1, ds.spec.dim)
        # standardization used population stats over ALL splits
        assert abs(float(flat.mean())) < 0.05
        assert 0.8 < float(flat.std()) < 1.2

    def test_labels_cover_classes(self):
        spec = data_mod.SPECS["m20"]
        ds = data_mod.make_dataset(spec)
        assert set(np.unique(ds.train_y)) == set(range(spec.n_classes))

    def test_tokens_within_sample_correlated(self):
        """Patch tokens share a per-sample latent -> within-sample token
        correlation must exceed across-sample correlation (the property
        that keeps Fig. 4's dataset-size axis meaningful)."""
        ds = data_mod.make_dataset(data_mod.SPECS["m20"])
        x = ds.train_x[:512]
        within = np.mean([
            np.corrcoef(x[i, 0], x[i, 1])[0, 1] for i in range(256)])
        across = np.mean([
            np.corrcoef(x[i, 0], x[i + 256, 0])[0, 1] for i in range(256)])
        assert within > across + 0.1

    def test_splits_disjoint_samples(self):
        ds = data_mod.make_dataset(data_mod.SPECS["m20"])
        # different splits must not share identical rows
        a = ds.train_x[:200].reshape(200, -1)
        b = ds.calib_x[:200].reshape(200, -1)
        d = np.abs(a[:, None, :] - b[None, :, :]).sum(axis=2)
        assert d.min() > 1e-3


class TestTensorFile:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "a": rng.normal(size=(3, 4, 5)).astype(np.float32),
            "b": rng.integers(0, 100, size=(7,)).astype(np.int32),
            "scalar_ish": np.asarray([3.25], np.float32),
        }
        p = tmp_path / "t.bin"
        write_tensors(p, tensors)
        back = read_tensors(p)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_bad_magic_rejected(self, tmp_path):
        p = tmp_path / "bad.bin"
        p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
        try:
            read_tensors(p)
            assert False, "should have raised"
        except ValueError:
            pass
