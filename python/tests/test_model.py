"""L2 correctness: model graphs, calibration steps, parameter accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile import model as model_mod
from compile.kernels import ref

from .conftest import make_programmed

T = data_mod.TOKENS


def tiny_spec():
    return model_mod.ModelSpec("tiny", n_blocks=3, width=16, n_classes=8,
                               ranks=(1, 2), with_lora=True)


def random_net(rng, spec):
    L, d, c = spec.n_blocks, spec.width, spec.n_classes
    wb = rng.normal(0, 0.5 / np.sqrt(d * L), size=(L, d, d)).astype(np.float32)
    wh = rng.normal(0, 1 / np.sqrt(d), size=(d, c)).astype(np.float32)
    return wb, wh


class TestPool:
    def test_pool_shape_and_value(self):
        x = np.arange(2 * T * 4, dtype=np.float32).reshape(2 * T, 4)
        p = np.asarray(model_mod.pool(jnp.asarray(x), 2))
        assert p.shape == (2, 4)
        np.testing.assert_allclose(p[0], x[:T].mean(axis=0), rtol=1e-6)

    def test_pool_of_constant_rows(self):
        x = jnp.ones((3 * T, 5))
        np.testing.assert_allclose(np.asarray(model_mod.pool(x, 3)), 1.0)


class TestStackedForwards:
    def test_model_fwd_equals_layerwise(self, rng):
        spec = tiny_spec()
        wb, wh = random_net(rng, spec)
        x = rng.normal(size=(4 * T, spec.width)).astype(np.float32)
        h = jnp.asarray(x)
        for l in range(spec.n_blocks):
            h = ref.teacher_block(h, jnp.asarray(wb[l]))
        want = ref.teacher_head(model_mod.pool(h, 4), jnp.asarray(wh))
        got = model_mod.model_fwd(jnp.asarray(x), jnp.asarray(wb),
                                  jnp.asarray(wh), batch=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_student_fwd_zero_drift_close_to_teacher(self, rng):
        spec = tiny_spec()
        wb, wh = random_net(rng, spec)
        gps, gns, invs = [], [], []
        for l in range(spec.n_blocks):
            _, gp, gn, inv = make_programmed(rng, spec.width, spec.width)
            # overwrite with the actual teacher weights programmed exactly
            w = wb[l]
            ws = 100.0 / (np.abs(w).max() + 1e-9)
            gps.append((np.maximum(w, 0) * ws).astype(np.float32))
            gns.append((np.maximum(-w, 0) * ws).astype(np.float32))
            invs.append(np.float32(1 / ws))
        w = wh
        ws = 100.0 / (np.abs(w).max() + 1e-9)
        gph = (np.maximum(w, 0) * ws).astype(np.float32)
        gnh = (np.maximum(-w, 0) * ws).astype(np.float32)
        invh = np.float32(1 / ws)

        x = rng.normal(size=(4 * T, spec.width)).astype(np.float32)
        teacher = model_mod.model_fwd(jnp.asarray(x), jnp.asarray(wb),
                                      jnp.asarray(wh), batch=4)
        fs = jnp.full((spec.n_blocks,), 8.0, jnp.float32)  # lsb ~ 0.06
        student = model_mod.student_fwd(
            jnp.asarray(x), jnp.asarray(np.stack(gps)),
            jnp.asarray(np.stack(gns)), jnp.asarray(np.array(invs)), fs,
            jnp.asarray(gph), jnp.asarray(gnh), jnp.asarray([invh]),
            jnp.asarray([8.0]), batch=4)
        np.testing.assert_allclose(np.asarray(student), np.asarray(teacher),
                                   atol=0.2)

    def test_dora_model_fwd_identity_adapters(self, rng):
        """meff=1, B=0  =>  dora_model_fwd == student_fwd."""
        spec = tiny_spec()
        L, d, c, r = spec.n_blocks, spec.width, spec.n_classes, 2
        wb, wh = random_net(rng, spec)
        gp = rng.uniform(0, 50, size=(L, d, d)).astype(np.float32)
        gn = rng.uniform(0, 50, size=(L, d, d)).astype(np.float32)
        inv = np.full((L,), 0.002, np.float32)
        fs = np.full((L,), 50.0, np.float32)
        gph = rng.uniform(0, 50, size=(d, c)).astype(np.float32)
        gnh = rng.uniform(0, 50, size=(d, c)).astype(np.float32)
        x = rng.normal(size=(4 * T, d)).astype(np.float32)
        a = rng.normal(0, 0.1, size=(L, d, r)).astype(np.float32)
        b = np.zeros((L, r, d), np.float32)
        meff = np.ones((L, d), np.float32)
        ah = rng.normal(0, 0.1, size=(d, r)).astype(np.float32)
        bh = np.zeros((r, c), np.float32)
        meffh = np.ones((c,), np.float32)
        args = [jnp.asarray(v) for v in
                (x, gp, gn, inv, fs, a, b, meff, gph, gnh)]
        got = model_mod.dora_model_fwd(
            *args, jnp.asarray([0.002]), jnp.asarray([50.0]),
            jnp.asarray(ah), jnp.asarray(bh), jnp.asarray(meffh), batch=4)
        want = model_mod.student_fwd(
            args[0], args[1], args[2], args[3], args[4], args[8], args[9],
            jnp.asarray([0.002]), jnp.asarray([50.0]), batch=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)


class TestCalibrationSteps:
    def _setup(self, rng, r=2, head=False):
        spec = tiny_spec()
        d = spec.width
        k = spec.n_classes if head else d
        w, gp, gn, inv = make_programmed(rng, d, k)
        batch = 4
        x = rng.normal(size=(batch * T, d)).astype(np.float32)
        # realistic target: the CLEAN layer's output; the student weight is
        # a drifted version of w (this is what calibration actually faces)
        drift = (w * (1 + 0.3 * rng.normal(size=w.shape))).astype(np.float32)
        ws = 100.0 / (np.abs(drift).max() + 1e-9)
        gp = (np.maximum(drift, 0) * ws).astype(np.float32)
        gn = (np.maximum(-drift, 0) * ws).astype(np.float32)
        inv = np.float32(1 / ws)
        if head:
            xp = x.reshape(batch, T, d).mean(axis=1)
            ft = (xp @ w).astype(np.float32)
            mask = np.ones((batch,), np.float32)
        else:
            ft = (np.maximum(x @ w, 0) + x).astype(np.float32)
            mask = np.ones((batch * T,), np.float32)
        a = rng.normal(0, 1 / np.sqrt(d), size=(d, r)).astype(np.float32)
        b = np.zeros((r, k), np.float32)
        wr = (gp - gn) * inv
        m = np.sqrt((wr * wr).sum(axis=0) + 1e-8).astype(np.float32)
        return (spec, batch,
                [jnp.asarray(v) for v in (x, mask, ft, gp, gn)],
                jnp.asarray([inv]), jnp.asarray([8.0]),
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(m))

    def _zeros_state(self, a, b, m):
        return [jnp.zeros_like(v) for v in (a, a, b, b, m, m)]

    @pytest.mark.parametrize("head", [False, True])
    def test_loss_decreases(self, rng, head):
        spec, batch, (x, mask, ft, gp, gn), inv, fs, a, b, m = \
            self._setup(rng, head=head)
        hb = batch if head else None
        st = self._zeros_state(a, b, m)
        losses = []
        for t in range(1, 41):
            out = model_mod.dora_step(
                x, mask, ft, gp, gn, inv, fs, a, b, m, *st,
                jnp.asarray([float(t)]), jnp.asarray([0.02]), head_batch=hb)
            a, b, m, *st, loss, n = out
            losses.append(float(loss[0]))
        assert losses[-1] < 0.5 * losses[0]

    def test_mask_excludes_padding(self, rng):
        """Step result must be invariant to garbage in masked rows."""
        spec, batch, (x, mask, ft, gp, gn), inv, fs, a, b, m = \
            self._setup(rng)
        mask = np.ones((batch * T,), np.float32)
        mask[2 * T:] = 0.0
        x2 = np.asarray(x).copy()
        x2[2 * T:] = 999.0
        st = self._zeros_state(a, b, m)
        t1 = model_mod.dora_step(
            x, jnp.asarray(mask), ft, gp, gn, inv, fs, a, b, m, *st,
            jnp.asarray([1.0]), jnp.asarray([0.02]), head_batch=None)
        t2 = model_mod.dora_step(
            jnp.asarray(x2), jnp.asarray(mask), ft, gp, gn, inv, fs, a, b,
            m, *st, jnp.asarray([1.0]), jnp.asarray([0.02]), head_batch=None)
        np.testing.assert_allclose(np.asarray(t1[0]), np.asarray(t2[0]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(t1[9]), np.asarray(t2[9]),
                                   atol=1e-6)

    def test_lora_step_loss_decreases(self, rng):
        spec, batch, (x, mask, ft, gp, gn), inv, fs, a, b, m = \
            self._setup(rng)
        st = [jnp.zeros_like(v) for v in (a, a, b, b)]
        losses = []
        for t in range(1, 41):
            out = model_mod.lora_step(
                x, mask, ft, gp, gn, inv, fs, a, b, *st,
                jnp.asarray([float(t)]), jnp.asarray([0.02]),
                head_batch=None)
            a, b, *st, loss = out
            losses.append(float(loss[0]))
        assert losses[-1] < 0.6 * losses[0]

    def test_dora_merge_matches_ref(self, rng):
        d, k, r = 16, 16, 2
        w, gp, gn, inv = make_programmed(rng, d, k)
        a = rng.normal(0, 0.1, size=(d, r)).astype(np.float32)
        b = rng.normal(0, 0.1, size=(r, k)).astype(np.float32)
        m = rng.uniform(0.5, 2, size=(k,)).astype(np.float32)
        meff = model_mod.dora_merge(jnp.asarray(gp), jnp.asarray(gn),
                                    jnp.asarray([inv]), jnp.asarray(a),
                                    jnp.asarray(b), jnp.asarray(m))
        wr = (gp - gn) * inv
        n = ref.dora_colnorm(jnp.asarray(wr), jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(meff), np.asarray(m / n),
                                   rtol=1e-5)

    def test_bp_step_loss_decreases(self, rng):
        spec = tiny_spec()
        wb, wh = random_net(rng, spec)
        batch = 8
        x = rng.normal(size=(batch * T, spec.width)).astype(np.float32)
        y = rng.integers(0, spec.n_classes, size=batch)
        onehot = np.eye(spec.n_classes, dtype=np.float32)[y]
        mask = np.ones((batch,), np.float32)
        wb, wh = jnp.asarray(wb), jnp.asarray(wh)
        st = [jnp.zeros_like(wb), jnp.zeros_like(wb),
              jnp.zeros_like(wh), jnp.zeros_like(wh)]
        losses = []
        for t in range(1, 31):
            out = model_mod.bp_step(
                jnp.asarray(x), jnp.asarray(mask), jnp.asarray(onehot),
                wb, wh, *st, jnp.asarray([float(t)]), jnp.asarray([0.01]),
                batch=batch)
            wb, wh, *st, loss = out
            losses.append(float(loss[0]))
        assert losses[-1] < 0.7 * losses[0]


class TestParameterAccounting:
    """Paper §IV-C: gamma = (d*r + r*k + k) / (d*k), per-network totals."""

    def test_gamma_single_layer_formula(self):
        # paper example shapes: gamma shrinks as the model grows
        m20 = model_mod.SPECS["m20"]
        m50 = model_mod.SPECS["m50"]
        assert m50.gamma(1) < m20.gamma(1)

    def test_gamma_monotone_in_rank(self):
        spec = model_mod.SPECS["m20"]
        gammas = [spec.gamma(r) for r in (1, 2, 4, 8)]
        assert all(g1 < g2 for g1, g2 in zip(gammas, gammas[1:]))

    def test_headline_ratio_band(self):
        """Paper headline: 2.34% trainable params (ResNet-50, r=4).

        Our m50 substitution must land in the same band (~1-6%) at the
        paper's rank so Table I reproduces its shape.
        """
        # Our m50 is width-96 (vs ResNet-50's up-to-2048-wide im2col
        # matrices), so gamma at r=4 lands ~9% rather than the paper's
        # 2.34%; the *relations* (shrinks with width, grows with r) are
        # what must hold. The paper's exact numbers are reproduced
        # analytically from real ResNet dims in rust metrics::params.
        g = model_mod.SPECS["m50"].gamma(4)
        assert 0.05 < g < 0.15, g

    def test_dora_params_count_exact(self):
        spec = tiny_spec()
        d, c, L, r = spec.width, spec.n_classes, spec.n_blocks, 2
        want = L * (d * r + r * d + d) + (d * r + r * c + c)
        assert spec.dora_params(r) == want


class TestEntryPointRegistry:
    def test_all_expected_entries_present(self):
        spec = model_mod.SPECS["m20"]
        eps = model_mod.entry_points(spec)
        for r in spec.ranks:
            for fam in ("dora_block", "dora_step_block", "dora_step_head",
                        "dora_model_fwd", "dora_merge_block",
                        "dora_merge_head", "lora_block", "lora_step_block",
                        "lora_step_head", "lora_model_fwd"):
                assert f"{fam}_m20_r{r}" in eps
        for fam in ("teacher_block", "teacher_head", "student_block",
                    "model_fwd", "student_fwd", "bp_step"):
            assert f"{fam}_m20" in eps

    def test_m50_has_no_lora(self):
        eps = model_mod.entry_points(model_mod.SPECS["m50"])
        assert not any(k.startswith("lora") for k in eps)

    def test_entry_point_shapes_lower(self):
        """Every tiny-spec entry point traces and lowers to StableHLO."""
        spec = tiny_spec()
        eps = model_mod.entry_points(spec)
        for name, (fn, args) in eps.items():
            jax.jit(fn).lower(*args)  # raises on shape bugs
