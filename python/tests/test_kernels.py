"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value ranges; every kernel must match ref to
float32 tolerance for all of them.  This is the CORE correctness signal of
the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import crossbar as xb
from compile.kernels import dora as dk
from compile.kernels import ref

from .conftest import make_programmed

ATOL = 2e-5


def _rand_case(seed, bsz, d, k, r):
    rng = np.random.default_rng(seed)
    w, gp, gn, inv = make_programmed(rng, d, k)
    x = rng.normal(0, 1, size=(bsz, d)).astype(np.float32)
    a = rng.normal(0, 0.1, size=(d, r)).astype(np.float32)
    b = rng.normal(0, 0.1, size=(r, k)).astype(np.float32)
    m = rng.uniform(0.5, 2.0, size=(k,)).astype(np.float32)
    fs = np.float32(max(4.0, 3 * np.sqrt(d) * 0.2))
    return (jnp.asarray(x), jnp.asarray(gp), jnp.asarray(gn),
            jnp.asarray([inv]), jnp.asarray([fs]), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(m))


shape_strategy = st.tuples(
    st.integers(0, 2 ** 31 - 1),            # seed
    st.sampled_from([1, 3, 8, 32, 64, 100]),  # batch
    st.sampled_from([16, 64, 96]),          # d
    st.sampled_from([16, 64, 100]),         # k
    st.sampled_from([1, 2, 4, 8]),          # r
)


class TestCrossbarKernel:
    @settings(max_examples=25, deadline=None)
    @given(shape_strategy)
    def test_matches_ref(self, case):
        seed, bsz, d, k, r = case
        x, gp, gn, inv, fs, *_ = _rand_case(seed, bsz, d, k, r)
        got = xb.crossbar_mvm(x, gp, gn, inv, fs, adc_bits=8)
        want = ref.crossbar_mvm(x, gp, gn, inv, fs, 8)
        np.testing.assert_allclose(got, want, atol=ATOL)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 6, 8, 12]))
    def test_adc_bits_sweep(self, seed, bits):
        x, gp, gn, inv, fs, *_ = _rand_case(seed, 16, 64, 64, 2)
        got = xb.crossbar_mvm(x, gp, gn, inv, fs, adc_bits=bits)
        want = ref.crossbar_mvm(x, gp, gn, inv, fs, bits)
        np.testing.assert_allclose(got, want, atol=ATOL)

    def test_quantization_levels(self):
        """ADC output must live on the quantization grid."""
        x, gp, gn, inv, fs, *_ = _rand_case(7, 8, 64, 64, 1)
        y = np.asarray(xb.crossbar_mvm(x, gp, gn, inv, fs, adc_bits=6))
        lsb = float(fs[0]) / 2 ** 5
        np.testing.assert_allclose(y / lsb, np.round(y / lsb), atol=1e-3)

    def test_zero_drift_recovers_weights(self):
        """No-drift programming + wide ADC ~= exact matmul."""
        rng = np.random.default_rng(0)
        w, gp, gn, inv = make_programmed(rng, 64, 64)
        x = rng.normal(size=(32, 64)).astype(np.float32)
        # 16-bit ADC with a full-scale just above the signal range:
        # lsb ~ 1e-3, so the readout is effectively exact.
        y = xb.crossbar_mvm(jnp.asarray(x), jnp.asarray(gp), jnp.asarray(gn),
                            jnp.asarray([inv]), jnp.asarray([32.0]),
                            adc_bits=16)
        np.testing.assert_allclose(np.asarray(y), x @ w, atol=2e-2)

    def test_batch_not_multiple_of_block(self):
        x, gp, gn, inv, fs, *_ = _rand_case(3, 70, 64, 64, 1)
        got = xb.crossbar_mvm(x, gp, gn, inv, fs, adc_bits=8, block_b=32)
        want = ref.crossbar_mvm(x, gp, gn, inv, fs, 8)
        np.testing.assert_allclose(got, want, atol=ATOL)

    def test_vmem_accounting(self):
        assert xb.vmem_bytes(64, 64, 64) == 4 * (64 * 64 * 3 + 64 * 64)
        assert xb.vmem_bytes(64, 96, 96) < xb.VMEM_BUDGET_BYTES


class TestDoraKernels:
    @settings(max_examples=25, deadline=None)
    @given(shape_strategy)
    def test_colnorm_matches_ref(self, case):
        seed, bsz, d, k, r = case
        x, gp, gn, inv, fs, a, b, m = _rand_case(seed, bsz, d, k, r)
        got = dk.dora_colnorm(gp, gn, inv, a, b)
        wr = ref.weights_from_conductance(gp, gn, jnp.reshape(inv, ()))
        want = ref.dora_colnorm(wr, a, b)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(shape_strategy)
    def test_fused_forward_matches_ref(self, case):
        seed, bsz, d, k, r = case
        x, gp, gn, inv, fs, a, b, m = _rand_case(seed, bsz, d, k, r)
        meff = m  # any positive vector works as a merged magnitude
        got = dk.dora_mvm(x, gp, gn, inv, fs, a, b, meff, adc_bits=8)
        want = ref.dora_linear_merged(x, gp, gn, inv, fs, a, b, meff, 8)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(shape_strategy)
    def test_vjp_forward_matches_ref(self, case):
        seed, bsz, d, k, r = case
        x, gp, gn, inv, fs, a, b, m = _rand_case(seed, bsz, d, k, r)
        got = dk.dora_linear_vjp(x, gp, gn, inv, fs, a, b, m, 8)
        want, _ = ref.dora_linear(x, gp, gn, inv, fs, a, b, m, 8)
        np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4, 8]))
    def test_hand_vjp_matches_autodiff(self, seed, r):
        """The hand-derived (A, B, M) gradients == jax.grad of the oracle."""
        x, gp, gn, inv, fs, a, b, m = _rand_case(seed, 16, 64, 64, r)
        tgt = jnp.zeros((16, 64), jnp.float32)

        def loss_ref(a_, b_, m_):
            y, _ = ref.dora_linear(x, gp, gn, inv, fs, a_, b_, m_, 8)
            return jnp.mean((y - tgt) ** 2)

        def loss_vjp(a_, b_, m_):
            y = dk.dora_linear_vjp(x, gp, gn, inv, fs, a_, b_, m_, 8)
            return jnp.mean((y - tgt) ** 2)

        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(a, b, m)
        gk = jax.grad(loss_vjp, argnums=(0, 1, 2))(a, b, m)
        for u, v in zip(gr, gk):
            scale = float(jnp.abs(u).max()) + 1e-12
            np.testing.assert_allclose(np.asarray(v), np.asarray(u),
                                       atol=1e-5 + 1e-4 * scale)

    def test_merge_identity_at_init(self):
        """B=0, M=||W_r||_c  =>  DoRA output == plain crossbar output."""
        x, gp, gn, inv, fs, a, b, m = _rand_case(5, 32, 64, 64, 4)
        b0 = jnp.zeros_like(b)
        wr = ref.weights_from_conductance(gp, gn, jnp.reshape(inv, ()))
        m0 = jnp.sqrt(jnp.sum(wr * wr, axis=0) + ref.NORM_EPS)
        y, n = ref.dora_linear(x, gp, gn, inv, fs, a, b0, m0, 8)
        z = ref.crossbar_mvm(x, gp, gn, inv, fs, 8)
        np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-4,
                                   rtol=1e-4)

    def test_lora_is_dora_without_magnitude(self):
        x, gp, gn, inv, fs, a, b, m = _rand_case(9, 8, 64, 64, 2)
        lora = ref.lora_linear(x, gp, gn, inv, fs, a, b, 8)
        ones_meff = jnp.ones((64,), jnp.float32)
        dora = ref.dora_linear_merged(x, gp, gn, inv, fs, a, b, ones_meff, 8)
        np.testing.assert_allclose(np.asarray(lora), np.asarray(dora),
                                   atol=ATOL)

    def test_dora_vmem_accounting(self):
        assert dk.dora_vmem_bytes(64, 96, 96, 8) < xb.VMEM_BUDGET_BYTES
