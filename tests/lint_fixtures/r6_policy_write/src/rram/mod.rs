//! R6 fixture support: the RRAM-write APIs. Defining them outside
//! serve/ is fine — only reachability *from* serve/ is the violation.

pub fn program_cell(_row: usize, _col: usize, _g: f64) {}

pub fn program_weights(_g: f64) {}
