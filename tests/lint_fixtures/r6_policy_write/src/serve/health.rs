//! R6 fixture: a "self-healing" fleet policy that rewrites RRAM from
//! serve/. Quarantine must be pure scheduling — drain the lane and
//! reroute traffic, never touch the crossbars — so both the direct
//! healer and the transitive spare-rotation path must be flagged by
//! the call-graph taint pass.

/// Direct violation: the policy "heals" a stuck cell by reprogramming
/// it in the field.
pub fn heal_stuck_cells(row: usize, col: usize, g: f64) {
    crate::rram::program_cell(row, col, g);
}

/// Helper that rewrites the whole array; seed for the transitive case.
fn rewrite_array(g: f64) {
    crate::rram::program_weights(g);
}

/// Transitive violation: rotating a spare device in via
/// `rewrite_array` reaches the write API through one hop.
pub fn rotate_spare_in(g: f64) {
    rewrite_array(g);
}
