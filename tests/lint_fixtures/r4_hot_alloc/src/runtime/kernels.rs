//! R4 fixture: a direct heap allocation inside the hot-path file set
//! (this path matches the real `runtime/kernels.rs`) must be flagged —
//! scratch buffers come from util::arena.

pub fn scratch(n: usize) -> Vec<f32> {
    vec![0.0f32; n]
}
