//! R1 fixture: a typed float reduction outside util/stats.rs,
//! util/tensor.rs, and runtime/kernels.rs must be flagged.

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
