//! R7 fixture: an adaptive recalibration policy that jitters its retry
//! backoff from wall-clock entropy. Policy time is counted in
//! simulated epochs and must replay bit-for-bit across reruns and
//! worker counts; an `Instant`-derived jitter makes every timeline
//! different, so the linter must flag it.

/// Exponential backoff with a wall-clock jitter term: nondeterministic
/// scheduling, exactly what the policy layer may never do.
pub fn backoff_epochs_with_jitter(base: u64, failures: u32) -> u64 {
    let backoff = base.max(1) << failures.saturating_sub(1).min(8);
    let jitter = std::time::Instant::now().elapsed().subsec_nanos() as u64;
    backoff + (jitter & 3)
}
