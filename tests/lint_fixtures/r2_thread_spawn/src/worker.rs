//! R2 fixture: direct thread spawning outside util/threads.rs,
//! util/arena.rs, and serve/ must be flagged.

pub fn fan_out() {
    let handle = std::thread::spawn(|| 42usize);
    let _ = handle.join();
}
