//! R7 fixture: a wall-clock read outside metrics/ and util/bench.rs
//! must be flagged — simulation code replays bit-for-bit off the
//! seeded util::rng only.

pub fn stamp() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
