//! R5 fixture: a bare `unsafe` block with no `// SAFETY:` comment, in a
//! file outside the unsafe allowlist — both R5 findings must fire.

pub fn peek(xs: &[f32]) -> f32 {
    unsafe { *xs.get_unchecked(0) }
}
