//! Twin of `r7_scenario_entropy`: the same wall-clock read, suppressed
//! by a justified R7 allow comment. Must lint clean — the escape hatch
//! works inside R4-hot files without loosening any other rule.

pub fn entropy_stream_seed(cell: u64) -> u64 {
    // lint:allow(R7) -- fixture: audited one-time boot entropy outside
    // any replayed simulation path
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .expect("clock before epoch");
    (t.as_nanos() as u64) ^ cell.wrapping_mul(0x9E3779B97F4A7C15)
}
