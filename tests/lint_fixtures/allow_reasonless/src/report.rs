//! Reason-less escape fixture: a `lint:allow` with no `-- reason` text
//! is itself a violation (ALLOW) and suppresses nothing, so the R1
//! finding underneath must still fire too.

pub fn mean(xs: &[f64]) -> f64 {
    // lint:allow(R1)
    xs.iter().sum::<f64>() / xs.len() as f64
}
