//! R3 fixture: any `HashMap`/`HashSet` use must be flagged — iteration
//! order is seeded-random per process.

use std::collections::HashMap;

pub fn tally(keys: &[u64]) -> usize {
    let mut seen: HashMap<u64, usize> = HashMap::new();
    for &k in keys {
        *seen.entry(k).or_insert(0) += 1;
    }
    seen.len()
}
