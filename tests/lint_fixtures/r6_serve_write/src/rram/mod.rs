//! R6 fixture support: the RRAM-write API itself. Defining it outside
//! serve/ is fine — only reachability *from* serve/ is the violation.

pub fn program_cell(_row: usize, _col: usize, _g: f64) {}
