//! R6 fixture: RRAM-write APIs reachable from serve/ — one direct call
//! and one transitive (through a same-file helper) — must both be
//! flagged by the call-graph taint pass.

/// Direct violation: a serve fn invoking a forbidden write token.
pub fn hotfix_weights(row: usize, col: usize, g: f64) {
    crate::rram::program_cell(row, col, g);
}

/// Helper that touches the write API; seed for the transitive case.
fn refresh_weights(g: f64) {
    crate::rram::program_cell(0, 0, g);
}

/// Transitive violation: reaches the write API via `refresh_weights`.
pub fn handle_maintenance(g: f64) {
    refresh_weights(g);
}
