//! R7 fixture: a scenario engine that seeds its fault streams from
//! wall-clock entropy. Fault injection must replay bit-for-bit from the
//! model seed via util::rng; `SystemTime` makes every run different, so
//! the linter must flag it even in the (R4-hot) scenario-engine file.

pub fn entropy_stream_seed(cell: u64) -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .expect("clock before epoch");
    (t.as_nanos() as u64) ^ cell.wrapping_mul(0x9E3779B97F4A7C15)
}
