//! Escape-hatch fixture: the same R1 violation as r1_float_reduction,
//! but suppressed by a justified `lint:allow` — the tree must lint
//! clean.

pub fn mean(xs: &[f64]) -> f64 {
    // lint:allow(R1) -- fixture: demonstrates a justified escape hatch
    xs.iter().sum::<f64>() / xs.len() as f64
}
