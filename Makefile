# rimc-dora build entry points.
#
# The default (native) build is hermetic: no Python, no XLA libraries, no
# artifacts directory required. `make artifacts` regenerates the optional
# AOT HLO artifacts for the PJRT backend and needs the JAX toolchain.

CARGO_DIR := rust

.PHONY: build test fmt clippy lint miri doc check bench-json \
        bench-baseline artifacts clean

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

clippy:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# Static invariant pass (rimc-lint R1-R7, DESIGN.md §8) over rust/src +
# rust/benches, plus its fixture self-test, plus the pinned clippy gate
# when a cargo toolchain is present. The python pass needs no Rust
# toolchain at all, so `make lint` is useful even on a bare box.
lint:
	python3 tools/rimc_lint.py
	python3 tools/test_rimc_lint.py
	@if command -v cargo >/dev/null 2>&1; then \
	  cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings; \
	else \
	  echo "lint: cargo not found; skipped clippy (static pass ran)"; \
	fi

# Dynamic UB/data-race backstop for the R5 surface: nightly Miri over
# the unsafe + concurrency core's unit tests (arena, thread pool,
# submit queue). Needs `rustup +nightly component add miri`; CI runs
# this on a schedule and a red run is a required failure, not
# best-effort noise.
miri:
	cd $(CARGO_DIR) && cargo +nightly miri test --lib -- \
	  util::arena util::threads serve::queue

# Public-API docs, warnings denied (same gate as CI).
doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

check: lint build test fmt clippy doc

# Run both JSON-emitting benches in smoke mode (serial + threaded, the
# same schedule CI uses) and schema-check + regression-gate the emitted
# BENCH_*.json against bench_baselines/ with the same script as CI.
bench-json:
	cd $(CARGO_DIR) && cargo bench --bench runtime_hotpath -- --smoke --threads 1
	cd $(CARGO_DIR) && mv BENCH_runtime_hotpath.json BENCH_runtime_hotpath_serial.json
	cd $(CARGO_DIR) && cargo bench --bench runtime_hotpath -- --smoke --threads 2
	cd $(CARGO_DIR) && cargo bench --bench serving_throughput -- --smoke --threads 2
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- scenarios --smoke --threads 1
	cd $(CARGO_DIR) && mv BENCH_scenarios.json BENCH_scenarios_serial.json
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- scenarios --smoke --threads 2
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- scenarios --grid --smoke --threads 2
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- serve \
	  --scenario full-stack --policy adaptive --smoke --threads 1
	cd $(CARGO_DIR) && mv BENCH_serve_policy.json BENCH_serve_policy_serial.json
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- serve \
	  --scenario full-stack --policy adaptive --smoke --threads 2
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- serve \
	  --cross-batch --smoke --threads 1
	cd $(CARGO_DIR) && mv BENCH_serve_batched.json BENCH_serve_batched_serial.json
	cd $(CARGO_DIR) && cargo run --release --bin rimc -- serve \
	  --cross-batch --smoke --threads 2
	cd $(CARGO_DIR) && python3 ../tools/bench_check.py \
	  BENCH_runtime_hotpath.json BENCH_runtime_hotpath_serial.json \
	  BENCH_serving_throughput.json BENCH_scenarios.json \
	  BENCH_scenarios_serial.json BENCH_scenarios_grid.json \
	  BENCH_serve_policy.json BENCH_serve_policy_serial.json \
	  BENCH_serve_batched.json BENCH_serve_batched_serial.json \
	  --baselines ../bench_baselines

# Promote the last bench-json run's results to the committed baselines
# (never edit those by hand — see bench_baselines/README.md).
bench-baseline:
	cp $(CARGO_DIR)/BENCH_runtime_hotpath.json bench_baselines/runtime_hotpath.json
	cp $(CARGO_DIR)/BENCH_runtime_hotpath_serial.json bench_baselines/runtime_hotpath_serial.json
	cp $(CARGO_DIR)/BENCH_serving_throughput.json bench_baselines/serving_throughput.json
	cp $(CARGO_DIR)/BENCH_scenarios.json bench_baselines/scenarios.json
	cp $(CARGO_DIR)/BENCH_scenarios_serial.json bench_baselines/scenarios_serial.json
	cp $(CARGO_DIR)/BENCH_scenarios_grid.json bench_baselines/scenarios_grid.json
	cp $(CARGO_DIR)/BENCH_serve_policy.json bench_baselines/serve_policy.json
	cp $(CARGO_DIR)/BENCH_serve_policy_serial.json bench_baselines/serve_policy_serial.json
	cp $(CARGO_DIR)/BENCH_serve_batched.json bench_baselines/serve_batched.json
	cp $(CARGO_DIR)/BENCH_serve_batched_serial.json bench_baselines/serve_batched_serial.json

# AOT HLO artifacts for the optional PJRT backend (`--features pjrt`).
# Requires python3 + jax; errors out with instructions when absent.
artifacts:
	@python3 -c "import jax" 2>/dev/null || { \
	  echo "error: 'make artifacts' needs the JAX toolchain (python3 + jax)"; \
	  echo "       to lower the compute graphs in python/compile to HLO."; \
	  echo "       Install jax (pip install jax) and re-run, or skip this"; \
	  echo "       target entirely: the default NATIVE backend needs no"; \
	  echo "       artifacts (see DESIGN.md \"Backends\")."; \
	  exit 1; }
	cd python && python3 -m compile.aot --outdir ../artifacts

clean:
	cd $(CARGO_DIR) && cargo clean
	rm -rf artifacts
